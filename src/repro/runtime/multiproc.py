"""Emulated multi-process meshes on one host (CPU, ``jax.distributed``).

The paper's runs span up to 128 GPUs; CI has one host. This module gives the
closest faithful stand-in: N real OS processes, each a ``jax.distributed``
participant with its own fake CPU devices, coordinating through the gloo CPU
collectives backend. Collectives genuinely cross process boundaries, a rank
can genuinely die (``os._exit``), and the survivors genuinely have to restart
from a checkpoint — the failure modes the resilience subsystem exists for,
none of which a single-process fake-device mesh can produce.

Topology is carried in ``REPRO_MP_*`` environment variables because the XLA
flags that create fake devices must be set *before* ``jax`` is imported:
the parent builds the env (``worker_env``), spawns plain ``python -c``
children (``launch_workers``), and each child calls ``init_from_env()`` as
its first jax-touching act.

Typical worker body::

    from repro.runtime import multiproc
    pid, nprocs = multiproc.init_from_env()   # joins the coordinator
    mesh = multiproc.global_mesh("data")       # spans ALL processes' devices
    ...train, checkpoint per-rank, maybe os._exit(1) on cue...
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
from typing import Dict, List, Optional, Sequence, Tuple

ENV_COORD = "REPRO_MP_COORD"
ENV_NPROCS = "REPRO_MP_NPROCS"
ENV_PID = "REPRO_MP_PID"


def distributed_available() -> Tuple[bool, str]:
    """(ok, reason): can this interpreter run localhost multi-process jax?

    Checked without initializing anything, so callers (tests, CI) can skip
    gracefully — and log why — on builds without ``jax.distributed`` or the
    gloo CPU collectives backend.
    """
    try:
        import jax
    except ImportError as e:  # pragma: no cover - jax is a hard dep elsewhere
        return False, f"jax not importable: {e}"
    if not hasattr(jax, "distributed"):
        return False, "jax.distributed missing in this jax build"
    try:
        jax.config.read("jax_cpu_collectives_implementation")
    except AttributeError:
        return False, "no jax_cpu_collectives_implementation config (gloo unavailable)"
    return True, "ok"


def free_port() -> int:
    """An OS-assigned free TCP port for the jax.distributed coordinator."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def worker_env(num_processes: int, process_id: int, coordinator_port: int,
               local_devices: int = 1,
               base_env: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """The environment for one spawned worker: CPU-only platform, fake-device
    count (must precede jax import — hence env, not API), and the REPRO_MP_*
    topology ``init_from_env`` reads."""
    env = dict(os.environ if base_env is None else base_env)
    env["JAX_PLATFORMS"] = "cpu"
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    flags.append(f"--xla_force_host_platform_device_count={local_devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    env[ENV_COORD] = f"localhost:{coordinator_port}"
    env[ENV_NPROCS] = str(num_processes)
    env[ENV_PID] = str(process_id)
    return env


def init_from_env(timeout_ms: int = 60_000) -> Tuple[int, int]:
    """Join the coordinator described by REPRO_MP_*. Call before any other jax
    use in a spawned worker. Returns (process_id, num_processes)."""
    import jax

    coord = os.environ[ENV_COORD]
    nprocs = int(os.environ[ENV_NPROCS])
    pid = int(os.environ[ENV_PID])
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(
        coordinator_address=coord, num_processes=nprocs, process_id=pid,
        initialization_timeout=max(1, timeout_ms // 1000))
    return pid, nprocs


def global_mesh(axis: str = "data"):
    """A 1-D mesh over every device of every participating process (the global
    device list ``jax.devices()`` — NOT the process-local subset)."""
    import jax
    from jax.sharding import Mesh

    import numpy as np

    return Mesh(np.array(jax.devices()), (axis,))


def launch_workers(worker_src: str, num_processes: int, *,
                   local_devices: int = 1, timeout: float = 240.0,
                   extra_env: Optional[Dict[str, str]] = None,
                   pythonpath: Optional[str] = None):
    """Spawn ``num_processes`` children running ``python -c worker_src`` with a
    shared fresh coordinator port; wait for all; return the list of
    ``CompletedProcess``-like results (returncode, stdout, stderr per rank).

    Workers that exit non-zero are NOT an error here — killing ranks is the
    point. A worker that outlives ``timeout`` is killed and reported with
    returncode ``-9``.
    """
    port = free_port()
    procs: List[subprocess.Popen] = []
    for pid in range(num_processes):
        env = worker_env(num_processes, pid, port, local_devices)
        if pythonpath:
            env["PYTHONPATH"] = pythonpath + os.pathsep + env.get("PYTHONPATH", "")
        env.update(extra_env or {})
        procs.append(subprocess.Popen(
            [sys.executable, "-c", worker_src], env=env,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))
    results = []
    for p in procs:
        try:
            out, err = p.communicate(timeout=timeout)
            rc = p.returncode
        except subprocess.TimeoutExpired:
            p.kill()
            out, err = p.communicate()
            rc = -9
        results.append(subprocess.CompletedProcess(p.args, rc, out, err))
    return results
