"""Pipeline race sanitizer — a checked mode for the one-step-stale contract.

The paper's pipeline is correct only under a strict timing discipline
(DESIGN.md §3): the train half consumes the representatives issued at step
t−1, the issue half writes the slot for step t+1, and a donated carry is dead
the moment the step runs. Nothing in the type system enforces any of that —
a driver that calls the halves in the wrong order, double-consumes a slot, or
touches a donated buffer produces silently-wrong numbers, not errors.

``PipelineRaceSanitizer`` is pure host-side bookkeeping around the compiled
step functions (it never touches array values, so fingerprints are
bit-identical sanitize on/off — pinned in tests/test_sanitizer.py):

  * **slot epochs** — every issue (write) and consume (read) of the pipeline
    slot appends to a monotone epoch log. The legal schedule is a strict
    alternation ``consume, issue, consume, issue, ...`` starting with the
    consume of the bootstrap sample; a stale step (bounded-staleness
    re-consume, ``make_stale_step``) is an allowed repeated read.
  * **same-step races** — an issue before the pending sample was ever
    consumed, a double issue (the pending sample is overwritten, i.e. lost),
    or a double non-stale consume each raise :class:`SanitizerError` with the
    recent epoch log in the message.
  * **donation safety** — inputs of a donating step are recorded at handoff;
    ``check_live`` walks a pytree and raises if any leaf is a deleted
    (donated) jax array, so use-after-donate surfaces as a precise error at
    the boundary instead of a backend crash mid-graph.
  * **rewind** — ``ResilientLoop`` restores a checkpoint mid-run; ``rewind``
    resets the clock to the restored step with the slot in the
    "freshly issued, ready to consume" state.

Enable with ``REPRO_SANITIZE=1`` (any value other than ``0``/``false``/
``no``/empty) or ``RunConfig(sanitize=True)``. The mode is wired through
``make_cl_step``, ``make_stale_step``, ``make_pipelined_halves``,
``launch/steps.py`` (pjit), ``ResilientLoop`` and ``OnlineLearner``.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, List, Optional, Tuple

import jax

_FALSY = ("", "0", "false", "no", "off")


class SanitizerError(RuntimeError):
    """A pipeline timing/donation invariant was violated.

    Deliberately NOT in ``TRANSIENT_EXCEPTIONS``: a race is a bug in the
    driver, not a fault to retry through — ``ResilientLoop`` re-raises it.
    """


def sanitize_enabled(run: Any = None) -> bool:
    """True if ``REPRO_SANITIZE`` is set truthy or ``run.sanitize`` is on."""
    env = os.environ.get("REPRO_SANITIZE", "").strip().lower()
    if env not in _FALSY:
        return True
    return bool(getattr(run, "sanitize", False))


class _Slot:
    __slots__ = ("last_op", "written_step", "consumed_step", "epochs")

    def __init__(self) -> None:
        # bootstrap: init_carry issued the (invalid-placeholder) pending
        # sample at step -1; the first real op must be its consume.
        self.last_op: str = "issue"
        self.written_step: int = -1
        self.consumed_step: int = -1
        self.epochs: List[Tuple[str, int]] = [("issue", -1)]

    def log(self, op: str, step: int, keep: int = 64) -> None:
        self.epochs.append((op, step))
        if len(self.epochs) > keep:
            del self.epochs[: len(self.epochs) - keep]


class PipelineRaceSanitizer:
    """Epoch bookkeeping for one pipeline (one trainer / one built step)."""

    def __init__(self, label: str = "pipeline") -> None:
        self.label = label
        self.step: int = 0  # logical step, advanced by tick()
        self.slots: Dict[str, _Slot] = {}
        self.races: int = 0  # total raises (for tests/telemetry)
        self._donated: Dict[int, Tuple[str, int]] = {}  # id(leaf) -> (tag, step)

    # -- slot epochs --------------------------------------------------------

    def _slot(self, name: str) -> _Slot:
        if name not in self.slots:
            self.slots[name] = _Slot()
        return self.slots[name]

    def consume(self, slot: str = "pipe", stale: bool = False) -> None:
        """The train half reads the pending sample."""
        s = self._slot(slot)
        if s.last_op == "consume" and not stale:
            self._race(
                f"slot `{slot}` consumed twice without a fresh issue "
                f"(pending sample from step {s.written_step} was already "
                f"read at step {s.consumed_step}); only a stale step may "
                "re-consume", s)
        if s.written_step >= self.step and not stale:
            self._race(
                f"same-step race on slot `{slot}`: consuming at step "
                f"{self.step} the sample issued at step {s.written_step} — "
                "the pipeline must be one step stale", s)
        s.consumed_step = self.step
        if not stale:
            s.last_op = "consume"
        s.log("consume:stale" if stale else "consume", self.step)

    def issue(self, slot: str = "pipe") -> None:
        """The issue half writes the next pending sample."""
        s = self._slot(slot)
        if s.last_op == "issue":
            self._race(
                f"slot `{slot}` issued twice in a row: the pending sample "
                f"written at step {s.written_step} was never consumed and is "
                "now overwritten (lost sample — issue/consume ran in the "
                "same step or the consume was skipped)", s)
        s.written_step = self.step
        s.last_op = "issue"
        s.log("issue", self.step)

    def tick(self) -> None:
        """End of one driver loop iteration."""
        self.step += 1

    def rewind(self, step: int) -> None:
        """ResilientLoop restored the checkpoint taken at ``step``: the
        restored slot holds the sample issued at step-1, ready to consume."""
        self.step = int(step)
        self._donated.clear()
        for s in self.slots.values():
            s.last_op = "issue"
            s.written_step = self.step - 1
            s.consumed_step = self.step - 1
            s.log("rewind", self.step)

    # -- donation -----------------------------------------------------------

    def note_donated(self, tree: Any, tag: str = "carry") -> None:
        """Record the inputs just handed to a donating step."""
        self._donated = {
            id(leaf): (tag, self.step)
            for leaf in jax.tree_util.tree_leaves(tree)
            if isinstance(leaf, jax.Array)
        }

    def check_live(self, tree: Any, what: str = "input") -> None:
        """Raise if any jax array leaf in ``tree`` was deleted (donated)."""
        for leaf in jax.tree_util.tree_leaves(tree):
            if isinstance(leaf, jax.Array) and leaf.is_deleted():
                tag, step = self._donated.get(id(leaf), ("a donating step", -1))
                where = f" at step {step}" if step >= 0 else ""
                self.races += 1
                raise SanitizerError(
                    f"[{self.label}] use-after-donate: {what} contains a "
                    f"buffer donated to {tag}{where}; donated arrays are "
                    "dead after handoff")

    # -- internals ----------------------------------------------------------

    def _race(self, message: str, s: _Slot) -> None:
        self.races += 1
        tail = ", ".join(f"{op}@{t}" for op, t in s.epochs[-8:])
        raise SanitizerError(
            f"[{self.label}] {message} (step {self.step}; recent epochs: "
            f"{tail})")


# ---------------------------------------------------------------------------
# Wrappers — the wiring points import these
# ---------------------------------------------------------------------------


def resolve_sanitizer(sanitize: Any, label: str) -> Optional[PipelineRaceSanitizer]:
    """Normalize a ``sanitize`` argument: an existing sanitizer is shared,
    True builds a fresh one, None defers to the env flag, False disables."""
    if isinstance(sanitize, PipelineRaceSanitizer):
        return sanitize
    if sanitize is None:
        sanitize = sanitize_enabled()
    return PipelineRaceSanitizer(label) if sanitize else None


def wrap_fused_step(step_fn, san: PipelineRaceSanitizer, *,
                    pipelined: bool, donate: bool, label: str = "fused step"):
    """``step(carry, batch, key)`` with slot + donation bookkeeping."""

    @functools.wraps(step_fn)
    def step(carry, batch, key):
        san.check_live(carry, f"{label} carry")
        if pipelined:
            san.consume()
        out = step_fn(carry, batch, key)
        if pipelined:
            san.issue()
        if donate:
            san.note_donated(carry)
        san.tick()
        return out

    step._sanitizer = san
    return step


def wrap_stale_step(stale_fn, san: PipelineRaceSanitizer, *,
                    label: str = "stale step"):
    """A stale step re-consumes the pending slot and issues nothing."""

    @functools.wraps(stale_fn)
    def step(carry, batch, key):
        san.check_live(carry, f"{label} carry")
        san.consume(stale=True)
        out = stale_fn(carry, batch, key)
        san.tick()
        return out

    step._sanitizer = san
    return step


def wrap_halves(train_half, issue_half, san: PipelineRaceSanitizer):
    """Split halves share one slot clock: the legal schedule per step is
    train (consume) then issue; the issue wrapper ends the step."""

    @functools.wraps(train_half)
    def train(params, opt, pipe, batch):
        san.check_live((params, opt, pipe), "train half inputs")
        san.consume()
        return train_half(params, opt, pipe, batch)

    @functools.wraps(issue_half)
    def issue(buffer, pipe, batch, key):
        san.check_live(buffer, "issue half buffer")
        san.issue()
        out = issue_half(buffer, pipe, batch, key)
        san.tick()
        return out

    train._sanitizer = san
    issue._sanitizer = san
    return train, issue


def wrap_built_step(fn, san: PipelineRaceSanitizer, *, pipelined: bool,
                    donated_args: int, label: str = "pjit step"):
    """Positional-signature wrapper for ``launch/steps.py`` built steps:
    the first ``donated_args`` positionals are state (donated), the last two
    are (batch, key)."""

    @functools.wraps(fn)
    def step(*args):
        san.check_live(args[:donated_args] if donated_args else args,
                       f"{label} state")
        if pipelined:
            san.consume()
        out = fn(*args)
        if pipelined:
            san.issue()
        if donated_args:
            san.note_donated(args[:donated_args])
        san.tick()
        return out

    step._sanitizer = san
    return step


__all__ = ["PipelineRaceSanitizer", "SanitizerError", "resolve_sanitizer",
           "sanitize_enabled", "wrap_built_step", "wrap_fused_step",
           "wrap_halves", "wrap_stale_step"]
