"""Autoscaling: a load signal drives elastic reshard of the training fleet.

The paper's elastic story (§VII: buffers survive worker-count changes) meets
an operational driver here: a ``TrafficSignal`` models offered load on the
train-while-serve fleet, and the ``Autoscaler`` turns utilization into scale
decisions — grow when sustained load exceeds capacity, shrink when it falls,
with hysteresis (distinct up/down thresholds) and a cooldown so transient
blips don't thrash the fleet. The decision layer is pure (no jax); applying a
decision is ``runtime.reshard_carry`` / ``reshard_tiered``, which pool and
re-deal the rehearsal buffers without losing contents (``scale_carry`` wraps
that with wall-clock timing for the fig7 benchmark).

Scale-down is the half that makes rehearsal interesting: evicting a worker
must not evict its shard of the replay memory. Pool + re-deal keeps every
stored representative (up to aggregate capacity), so accuracy@N after a
2→4→2 excursion matches the flat-fleet run — the invariant
``benchmarks/fig7_scalability.py`` measures and ``tests/test_multiproc.py``
pins across a process death.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import List, Optional, Tuple


class TrafficSignal:
    """Synthetic offered-load trace, pure in (pattern, step) — replayable.

    Patterns (all oscillate between ``low`` and ``high`` with ``period``):
      ``square`` — load steps between low and high each half-period (the
          grow-then-shrink excursion fig7 drives);
      ``ramp``   — sawtooth: linear climb, instant drop;
      ``sine``   — smooth oscillation.
    """

    def __init__(self, pattern: str = "square", period: int = 40,
                 low: float = 1.0, high: float = 4.0):
        if pattern not in ("square", "ramp", "sine"):
            raise ValueError(f"unknown traffic pattern {pattern!r}")
        if period < 2:
            raise ValueError(f"period must be >= 2, got {period}")
        self.pattern = pattern
        self.period = period
        self.low = float(low)
        self.high = float(high)

    def load(self, step: int) -> float:
        phase = (step % self.period) / self.period
        if self.pattern == "square":
            x = 1.0 if phase >= 0.5 else 0.0
        elif self.pattern == "ramp":
            x = phase
        else:  # sine
            x = 0.5 * (1.0 - math.cos(2.0 * math.pi * phase))
        return self.low + (self.high - self.low) * x


@dataclasses.dataclass
class Autoscaler:
    """Utilization → worker-count decisions with hysteresis and cooldown.

    utilization = load / (workers * capacity_per_worker). Above
    ``upscale_threshold`` the fleet grows to the smallest count that brings
    utilization under it; below ``downscale_threshold`` it shrinks likewise
    (the gap between the two thresholds is the hysteresis band — a fleet
    sitting between them never moves). ``cooldown_steps`` must elapse between
    consecutive decisions. ``observe`` returns the new count or None.
    """

    min_workers: int = 1
    max_workers: int = 4
    capacity_per_worker: float = 1.0
    upscale_threshold: float = 0.9
    downscale_threshold: float = 0.45
    cooldown_steps: int = 5

    def __post_init__(self):
        if not (0.0 < self.downscale_threshold < self.upscale_threshold <= 1.0):
            raise ValueError(
                "need 0 < downscale_threshold < upscale_threshold <= 1, got "
                f"{self.downscale_threshold} / {self.upscale_threshold}")
        if self.min_workers < 1 or self.max_workers < self.min_workers:
            raise ValueError(
                f"bad worker bounds [{self.min_workers}, {self.max_workers}]")
        self._last_change: Optional[int] = None
        self.events: List[Tuple[int, int, int]] = []  # (step, old, new)

    def _clamp(self, n: int) -> int:
        return max(self.min_workers, min(self.max_workers, n))

    def desired(self, load: float) -> int:
        """The smallest fleet keeping utilization under upscale_threshold."""
        need = load / (self.capacity_per_worker * self.upscale_threshold)
        return self._clamp(max(1, math.ceil(need - 1e-9)))

    def observe(self, step: int, load: float, current: int) -> Optional[int]:
        if (self._last_change is not None
                and step - self._last_change < self.cooldown_steps):
            return None
        util = load / (current * self.capacity_per_worker)
        target = None
        if util > self.upscale_threshold:
            target = self.desired(load)
        elif util < self.downscale_threshold:
            cand = self.desired(load)
            # only shrink if the smaller fleet stays under the UP threshold —
            # else the next observation would immediately grow back (thrash)
            if cand < current:
                target = cand
        if target is None or target == current:
            return None
        self._last_change = step
        self.events.append((step, current, target))
        from repro.obs import get_event_bus
        get_event_bus().publish(
            "autoscale", source="autoscaler", step=step, old=current,
            new=target, load=float(load), utilization=float(util),
            upscale_threshold=self.upscale_threshold,
            downscale_threshold=self.downscale_threshold,
            cooldown_steps=self.cooldown_steps)
        return target


def scale_carry(carry, n_new: int, policy=None):
    """Apply a scale decision to a live TrainCarry: pool + re-deal the buffers
    (flat or tiered — ``reshard_carry`` dispatches) across ``n_new`` workers.
    Returns (new_carry, seconds) — the reshard latency fig7 reports."""
    import jax
    import jax.numpy as jnp

    from repro.obs import get_event_bus, get_tracer
    from repro.runtime.elastic import reshard_carry

    t0 = time.perf_counter()
    with get_tracer().span("reshard", cat="elastic", n_new=n_new):
        new_carry = reshard_carry(carry, n_new, policy=policy)
        # decommit: params/opt pass through reshard still committed to the old
        # mesh's devices; a jit compiled for the new mesh refuses mixed-committed
        # inputs. The host round-trip is part of the real reshard cost.
        new_carry = jax.tree_util.tree_map(jnp.asarray, jax.device_get(new_carry))
        jax.block_until_ready(jax.tree_util.tree_leaves(new_carry))
    seconds = time.perf_counter() - t0
    get_event_bus().publish("reshard", source="scale_carry", n_new=n_new,
                            seconds=seconds)
    return new_carry, seconds
