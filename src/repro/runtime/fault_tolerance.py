"""Fault tolerance + straggler mitigation for the training runtime.

At thousands of nodes, three failure classes dominate; the corresponding mechanisms:

1. **Hard failures** (node dies) → checkpoint/restart. ``ResilientLoop`` wraps the
   step function: on exception it restores the last checkpoint, rewinds the data
   cursor, and resumes. Restart is bit-exact because the data stream and all RNG are
   pure functions of (seed, cursor/step).
2. **Transient failures** (preemption, flaky link, pjit/IO hiccup) → bounded retry
   with state rollback and exponential backoff. ``retry_on`` is an exception
   allowlist (default: :data:`TRANSIENT_EXCEPTIONS`); anything outside it propagates
   immediately — a deterministic error (shape mismatch, NaN guard) would fail
   identically on every replay, so retrying it only burns the restart budget.
3. **Stragglers** in the rehearsal service → *bounded staleness*: the paper's async
   design already means training never blocks on sampling; if the exchange for step
   t+1 is late (simulated via ``delay_prob``, or detected by the wall-clock
   ``step_timeout``), the step reuses the previous in-flight representatives instead
   of waiting (``stale_step_fn``, built by ``repro.strategy.make_stale_step``).
   Accuracy impact is negligible (representatives are i.i.d. samples either way);
   ``max_staleness`` bounds consecutive reuses, so the paper's "training only waits
   if the service can't keep up" becomes "training *never* waits, staleness is
   bounded".

Rollback is free because steps are pure: a step either completes and its carry is
committed, or the exception discards the partially-donated carry and the next
attempt starts from the restored checkpoint arrays (the checkpoint holds host-side
copies, never aliases of donated device buffers).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional, Sequence, Tuple, Type

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.utils.logging import get_logger

log = get_logger("repro.runtime")


class InjectedFailure(RuntimeError):
    """Raised by tests / chaos hooks to simulate node failure."""


def _transient_exceptions() -> Tuple[Type[BaseException], ...]:
    """The default ``retry_on`` allowlist: chaos injections plus the exception
    classes a preemption / flaky interconnect / remote filesystem actually
    surfaces as (OSError covers IOError; XlaRuntimeError is what a pjit step
    raises when a participant drops mid-collective)."""
    excs: list = [InjectedFailure, OSError, ConnectionError, TimeoutError]
    try:  # jaxlib layout moved across versions; absence just narrows the list
        from jax.errors import JaxRuntimeError  # type: ignore[attr-defined]

        excs.append(JaxRuntimeError)
    except ImportError:
        try:
            from jaxlib.xla_extension import XlaRuntimeError  # type: ignore

            excs.append(XlaRuntimeError)
        except ImportError:
            pass
    return tuple(excs)


TRANSIENT_EXCEPTIONS: Tuple[Type[BaseException], ...] = _transient_exceptions()


@dataclasses.dataclass
class ResilientLoop:
    """Checkpointed training loop with bounded-retry restart on failure.

    ``run`` drives ``step_fn(carry, batch, key) -> (carry, metrics)`` for
    ``num_steps`` steps with periodic full-carry checkpoints. On an allowlisted
    exception it restores the last checkpoint, truncates the metrics history to
    the restored cursor (entries recorded for rolled-back steps would otherwise
    duplicate on replay), sleeps an exponential backoff, and replays — bit-exact,
    because batches and RNG derive from the absolute step id.

    ``step_timeout`` (seconds, wall-clock) + ``straggler`` + ``stale_step_fn``
    form the bounded-staleness path: a step that overruns the budget marks the
    rehearsal exchange as straggling, and the next step runs ``stale_step_fn``
    (same optimizer step, but consuming the carried in-flight representatives
    again and skipping the exchange) instead of blocking on a fresh sample.
    """

    step_fn: Callable  # (carry, batch, key) -> (carry, metrics)
    ckpt: CheckpointManager
    checkpoint_every: int = 50
    max_restarts: int = 3
    retry_on: Optional[Sequence[Type[BaseException]]] = None  # None -> TRANSIENT_EXCEPTIONS
    backoff_base: float = 0.0  # restart r sleeps min(backoff_max, base * 2**(r-1))
    backoff_max: float = 30.0
    step_timeout: float = 0.0  # wall-clock budget per step; 0 disables
    straggler: Optional["StragglerPolicy"] = None
    stale_step_fn: Optional[Callable] = None  # (carry, batch, key) -> (carry, metrics)
    sleep_fn: Callable[[float], None] = time.sleep  # injectable for tests

    def _backoff(self, restarts: int) -> float:
        if self.backoff_base <= 0.0:
            return 0.0
        return min(self.backoff_max, self.backoff_base * (2.0 ** (restarts - 1)))

    def run(self, carry, batch_fn, key, num_steps: int, start_step: int = 0,
            failure_hook: Optional[Callable[[int], None]] = None):
        """``batch_fn(step) -> batch``. Returns (carry, metrics_history, restarts).

        Per-run counters land on ``self.stats``: restarts, stale_steps,
        restore_seconds (wall-clock spent in restore, the "restart cost").
        """
        retry_on = tuple(self.retry_on) if self.retry_on is not None \
            else TRANSIENT_EXCEPTIONS
        restarts = 0
        stale_steps = 0
        restore_seconds = 0.0
        step = start_step
        history: list = []
        self.ckpt.save(step, carry, {"cursor": step, "history_len": 0})
        while step < start_step + num_steps:
            try:
                if failure_hook is not None:
                    failure_hook(step)  # chaos injection point
                batch = batch_fn(step)
                use_stale = (
                    self.straggler is not None
                    and self.stale_step_fn is not None
                    and not self.straggler.use_fresh()
                )
                fn = self.stale_step_fn if use_stale else self.step_fn
                t0 = time.monotonic()
                carry, metrics = fn(carry, batch, jax.random.fold_in(key, step))
                if self.step_timeout > 0.0:
                    jax.block_until_ready(jax.tree_util.tree_leaves(carry)[0])
                    if (time.monotonic() - t0 > self.step_timeout
                            and self.straggler is not None):
                        # over budget: the exchange for t+1 is presumed late —
                        # flag it so the next step reuses instead of waiting
                        self.straggler.record_slow()
                stale_steps += int(use_stale)
                step += 1
                # history BEFORE the checkpoint: the snapshot's history_len then
                # counts exactly the committed steps, so restore can truncate
                # replayed entries instead of duplicating them
                history.append({k: float(v) for k, v in metrics.items()})
                if step % self.checkpoint_every == 0:
                    jax.block_until_ready(jax.tree_util.tree_leaves(carry)[0])
                    self.ckpt.save(step, carry,
                                   {"cursor": step, "history_len": len(history)})
            except retry_on as e:
                from repro.runtime.sanitizer import SanitizerError
                if isinstance(e, SanitizerError):
                    # a race is a driver bug, not a fault: replaying it would
                    # fail identically, so it always propagates — even when a
                    # caller passes a broad retry_on (e.g. RuntimeError)
                    raise
                restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.max_restarts}") from e
                pause = self._backoff(restarts)
                from repro.obs import get_event_bus, get_tracer
                t0 = time.monotonic()
                with get_tracer().span("restore", cat="resilience",
                                       restart=restarts):
                    carry, meta = self.ckpt.restore(carry)
                restore_seconds += time.monotonic() - t0
                step = int(meta["cursor"])  # rewind the data cursor with the state
                del history[int(meta.get("history_len", len(history))):]
                # keep the sanitizer's slot clock in sync with the restored
                # step (the restored pipe holds a ready-to-consume sample)
                san = getattr(self.step_fn, "_sanitizer", None)
                if san is not None:
                    san.rewind(step)
                get_event_bus().publish(
                    "restart", source="resilient_loop", step=step,
                    restarts=restarts, error=type(e).__name__, backoff_s=pause)
                log.warning("failure at restart %d (%s); restored step %d, "
                            "backoff %.2fs", restarts, e, step, pause)
                if pause > 0.0:
                    self.sleep_fn(pause)
        self.ckpt.wait()
        self.stats = {"restarts": restarts, "stale_steps": stale_steps,
                      "restore_seconds": restore_seconds}
        return carry, history, restarts


class StragglerPolicy:
    """Bounded-staleness rehearsal: decide whether to consume fresh representatives.

    ``delay_prob`` simulates a straggling rehearsal exchange (late collective / slow
    peer); ``record_slow()`` marks a real one (a step that blew its wall-clock
    budget — see ``ResilientLoop.step_timeout``). When straggling, the trainer
    reuses the previous in-flight representatives — it NEVER blocks.
    ``max_staleness`` bounds consecutive reuses; beyond it we fall back to fresh
    (i.e., accept the wait — in practice never reached at delay probabilities
    below ~90%)."""

    def __init__(self, delay_prob: float = 0.0, max_staleness: int = 4, seed: int = 0):
        self.delay_prob = delay_prob
        self.max_staleness = max_staleness
        self._rng = np.random.default_rng(seed)
        self.staleness = 0
        self.reuses = 0
        self._pending_slow = False

    def record_slow(self) -> None:
        """Flag the in-flight exchange as late (wall-clock overrun): the next
        ``use_fresh`` answers False (reuse) unless the staleness bound forces a
        fresh consume."""
        self._pending_slow = True

    def use_fresh(self) -> bool:
        slow = self._pending_slow
        self._pending_slow = False
        if slow or (self.delay_prob and self._rng.random() < self.delay_prob):
            if self.staleness < self.max_staleness:
                self.staleness += 1
                self.reuses += 1
                from repro.obs import get_event_bus
                get_event_bus().publish(
                    "stale_dispatch", source="straggler",
                    staleness=self.staleness, detected=bool(slow))
                return False
        self.staleness = 0
        return True
