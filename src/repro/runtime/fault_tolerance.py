"""Fault tolerance + straggler mitigation for the training runtime.

At thousands of nodes, three failure classes dominate; the corresponding mechanisms:

1. **Hard failures** (node dies) → checkpoint/restart. ``ResilientLoop`` wraps the
   step function: on exception it restores the last checkpoint, rewinds the data
   cursor, and resumes. Restart is bit-exact because the data stream and all RNG are
   pure functions of (seed, cursor/step).
2. **Transient failures** (preemption, flaky link) → bounded retry with state rollback
   (the step either completes and is committed, or the carry is discarded — pure
   functional steps make rollback free).
3. **Stragglers** in the rehearsal service → *bounded staleness*: the paper's async
   design already means training never blocks on sampling; if the exchange for step
   t+1 is late (simulated here — on real hardware this is a late collective), the
   step reuses the previous in-flight representatives instead of waiting. Accuracy
   impact is negligible (representatives are i.i.d. samples either way); the paper's
   "training only waits if the service can't keep up" becomes "training *never*
   waits, staleness is bounded by 1 extra step".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.utils.logging import get_logger

log = get_logger("repro.runtime")


class InjectedFailure(RuntimeError):
    """Raised by tests / chaos hooks to simulate node failure."""


@dataclasses.dataclass
class ResilientLoop:
    """Checkpointed training loop with automatic restart on failure."""

    step_fn: Callable  # (carry, batch, key) -> (carry, metrics)
    ckpt: CheckpointManager
    checkpoint_every: int = 50
    max_restarts: int = 3

    def run(self, carry, batch_fn, key, num_steps: int, start_step: int = 0,
            failure_hook: Optional[Callable[[int], None]] = None):
        """``batch_fn(step) -> batch``. Returns (carry, metrics_history, restarts)."""
        restarts = 0
        step = start_step
        history = []
        self.ckpt.save(step, carry, {"cursor": step})
        last_good = step
        while step < start_step + num_steps:
            try:
                if failure_hook is not None:
                    failure_hook(step)  # chaos injection point
                batch = batch_fn(step)
                carry, metrics = self.step_fn(carry, batch, jax.random.fold_in(key, step))
                step += 1
                if step % self.checkpoint_every == 0:
                    jax.block_until_ready(jax.tree_util.tree_leaves(carry)[0])
                    self.ckpt.save(step, carry, {"cursor": step})
                    last_good = step
                history.append({k: float(v) for k, v in metrics.items()})
            except InjectedFailure as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError(f"exceeded max_restarts={self.max_restarts}") from e
                log.warning("failure at step %d (%s); restoring step %d", step, e, last_good)
                carry, meta = self.ckpt.restore(carry)
                step = int(meta["cursor"])  # rewind the data cursor with the state
        self.ckpt.wait()
        return carry, history, restarts


class StragglerPolicy:
    """Bounded-staleness rehearsal: decide whether to consume fresh representatives.

    ``delay_prob`` simulates a straggling rehearsal exchange (late collective / slow
    peer). When straggling, the trainer reuses the previous in-flight representatives —
    it NEVER blocks. ``max_staleness`` bounds consecutive reuses; beyond it we fall
    back to fresh (i.e., accept the wait — in practice never reached at delay
    probabilities below ~90%)."""

    def __init__(self, delay_prob: float = 0.0, max_staleness: int = 4, seed: int = 0):
        self.delay_prob = delay_prob
        self.max_staleness = max_staleness
        self._rng = np.random.default_rng(seed)
        self.staleness = 0
        self.reuses = 0

    def use_fresh(self) -> bool:
        if self.delay_prob and self._rng.random() < self.delay_prob:
            if self.staleness < self.max_staleness:
                self.staleness += 1
                self.reuses += 1
                return False
        self.staleness = 0
        return True
