"""Elastic scaling: resume a run on a different worker count.

The contract: everything in the carry is either *replicated* (params, optimizer — a
new worker count changes only how GSPMD lays them out) or *per-worker* (rehearsal
buffer, in-flight representatives — redistributed by ``reshard_buffer``). The data
pipeline re-shards trivially (cursor-deterministic streams).

Shrink (N→N′<N): buffer contents are pooled per bucket and re-dealt; aggregate
capacity drops to N′·S_max exactly as the paper's scaling law predicts.
Grow (N→N′>N): new workers start with partially-filled buffers and fill via Alg-1.

Tiered stores reshard tier-by-tier: the hot tier exactly like a flat buffer
(policy aux rebuilt per worker via ``Policy.reshard_aux``), the cold tier's int8
rows pooled + re-dealt the same way (its reservoir archive carries no aux), and
the demotion staging slot's pending rows pooled across workers and re-dealt
round-robin — overflow beyond the per-worker ``stage_rows`` is dropped, exactly
the bounded-staging semantics of ``tiered._pack_stage``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.buffer.tiered import TieredState
from repro.checkpoint.manager import reshard_buffer
from repro.core.rehearsal import BufferState
from repro.strategy import PipelinedRehearsalCarry, TrainCarry

# Strategy aux fields (DER stored logits, grasp_embed embeddings) are ordinary
# record leaves: they pool + re-deal with their records through every path
# below, and the hot-overflow demotion int8-encodes them like any float leaf.


def _reshard_buffer_state(buffer: BufferState, n_new: int, policy) -> BufferState:
    """Pool + re-deal one BufferState (leaves [N, K, slots, ...]) to ``n_new``
    workers, rebuilding policy aux for the compacted slots."""
    new_data, new_counts = reshard_buffer(buffer.data, np.asarray(buffer.counts),
                                          n_new)
    n_old, k = np.asarray(buffer.counts).shape
    seen = np.asarray(buffer.seen).sum(axis=0, keepdims=True)
    new_seen = np.broadcast_to(seen // n_new, (n_new, k)).copy()

    if jax.tree_util.tree_leaves(buffer.aux):
        from repro.buffer import resolve_policy

        if policy is None:
            raise ValueError(
                "the buffer carries policy aux state; pass the policy (name or "
                "Policy) so reshard_carry can rebuild it for the re-dealt slots"
            )
        pol = resolve_policy(policy)
        per_worker = [
            pol.reshard_aux(
                jax.tree_util.tree_map(lambda x: jnp.asarray(np.asarray(x)[w]),
                                       new_data),
                new_counts[w],
            )
            for w in range(n_new)
        ]
        aux = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_worker)
    else:
        aux = buffer.aux
    return BufferState(
        data=jax.tree_util.tree_map(jnp.asarray, new_data),
        counts=jnp.asarray(new_counts),
        seen=jnp.asarray(new_seen.astype(np.int32)),
        aux=aux,
    )


def _reshard_stage(stage, stage_labels, stage_valid, n_new: int):
    """Re-deal the pending demotions ([N, rows, ...] leaves) round-robin across
    the new worker axis. Valid rows beyond the aggregate ``n_new * rows``
    staging capacity are dropped — the same records a full staging slot would
    have dropped at the next eviction burst."""
    labels = np.asarray(stage_labels)
    valid = np.asarray(stage_valid)
    n_old, rows = valid.shape
    leaves, treedef = jax.tree_util.tree_flatten(stage)
    leaves = [np.asarray(l) for l in leaves]

    new_leaves = [np.zeros((n_new,) + l.shape[1:], l.dtype) for l in leaves]
    new_labels = np.zeros((n_new, rows), labels.dtype)
    new_valid = np.zeros((n_new, rows), bool)
    pool = [(w, r) for w in range(n_old) for r in range(rows) if valid[w, r]]
    for j, (w, r) in enumerate(pool):
        dst_w, dst_r = j % n_new, j // n_new
        if dst_r >= rows:
            break  # aggregate staging capacity shrank: drop the tail
        for l_old, l_new in zip(leaves, new_leaves):
            l_new[dst_w, dst_r] = l_old[w, r]
        new_labels[dst_w, dst_r] = labels[w, r]
        new_valid[dst_w, dst_r] = True
    return (
        jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(l) for l in new_leaves]),
        jnp.asarray(new_labels),
        jnp.asarray(new_valid),
    )


def reshard_tiered(state: TieredState, n_new: int, policy=None) -> TieredState:
    """Redistribute a distributed TieredState (leaves [N, ...]) to ``n_new``
    workers, tier by tier:

      * hot rows are pooled per bucket and dealt round-robin; rows beyond the
        new aggregate hot capacity are *demoted* — int8-encoded and appended to
        the cold pool, exactly what the store itself does on eviction — rather
        than destroyed (so a shrink preserves every record the cold tier can
        absorb);
      * cold rows (existing archive first, fresh demotions after) are pooled +
        dealt the same way; only rows beyond the new aggregate cold capacity
        are dropped;
      * staging rows (pending demotions) pool + re-deal with overflow dropped
        (bounded-queue semantics);
      * hot policy aux is rebuilt per worker via ``Policy.reshard_aux``
        (cloned cursors/distances would be misaligned with the re-dealt slots).
    """
    from repro.core import compression as comp

    hot_counts = np.asarray(state.hot.counts)
    cold_counts = np.asarray(state.cold.counts)
    n_old, k = hot_counts.shape
    hot_leaves, hot_def = jax.tree_util.tree_flatten(state.hot.data)
    cold_leaves, cold_def = jax.tree_util.tree_flatten(state.cold.data)
    hot_leaves = [np.asarray(l) for l in hot_leaves]
    cold_leaves = [np.asarray(l) for l in cold_leaves]
    hot_slots = hot_leaves[0].shape[2]
    cold_slots = cold_leaves[0].shape[2]
    item_spec = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(np.asarray(l).shape[3:], l.dtype),
        state.hot.data)

    new_hot = [np.zeros((n_new,) + l.shape[1:], l.dtype) for l in hot_leaves]
    new_cold = [np.zeros((n_new,) + l.shape[1:], l.dtype) for l in cold_leaves]
    new_hot_counts = np.zeros((n_new, k), np.int32)
    new_cold_counts = np.zeros((n_new, k), np.int32)
    for b in range(k):
        pool = [(w, s) for w in range(n_old) for s in range(int(hot_counts[w, b]))]
        keep, overflow = pool[: n_new * hot_slots], pool[n_new * hot_slots:]
        for j, (w, s) in enumerate(keep):
            dst_w, dst_s = j % n_new, j // n_new
            for l_old, l_new in zip(hot_leaves, new_hot):
                l_new[dst_w, b, dst_s] = l_old[w, b, s]
            new_hot_counts[dst_w, b] = max(new_hot_counts[dst_w, b], dst_s + 1)

        # cold pool: the existing archive first, fresh demotions last (they are
        # the first to go if the new aggregate cold capacity cannot hold all)
        cold_pool = [("cold", w, s) for w in range(n_old)
                     for s in range(int(cold_counts[w, b]))]
        demoted = None
        if overflow:
            rows = jax.tree_util.tree_unflatten(
                hot_def,
                [np.stack([l[w, b, s] for (w, s) in overflow]) for l in hot_leaves])
            demoted = [np.asarray(l) for l in jax.tree_util.tree_leaves(
                comp.encode_batch(
                    jax.tree_util.tree_map(jnp.asarray, rows), item_spec))]
            cold_pool += [("demoted", 0, i) for i in range(len(overflow))]
        for j, (src, w, s) in enumerate(cold_pool[: n_new * cold_slots]):
            dst_w, dst_s = j % n_new, j // n_new
            src_leaves = cold_leaves if src == "cold" else demoted
            for l_old, l_new in zip(src_leaves, new_cold):
                l_new[dst_w, b, dst_s] = l_old[w, b, s] if src == "cold" else l_old[s]
            new_cold_counts[dst_w, b] = max(new_cold_counts[dst_w, b], dst_s + 1)

    def seen_of(seen):
        pooled = np.asarray(seen).sum(axis=0, keepdims=True)
        return jnp.asarray(
            np.broadcast_to(pooled // n_new, (n_new, k)).astype(np.int32).copy())

    hot_data = jax.tree_util.tree_unflatten(
        hot_def, [jnp.asarray(l) for l in new_hot])
    if jax.tree_util.tree_leaves(state.hot.aux):
        from repro.buffer import resolve_policy

        if policy is None:
            raise ValueError(
                "the hot tier carries policy aux state; pass the policy so "
                "reshard_tiered can rebuild it for the re-dealt slots")
        pol = resolve_policy(policy)
        per_worker = [
            pol.reshard_aux(
                jax.tree_util.tree_map(lambda x: x[w], hot_data),
                new_hot_counts[w])
            for w in range(n_new)
        ]
        hot_aux = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_worker)
    else:
        hot_aux = state.hot.aux
    hot = BufferState(hot_data, jnp.asarray(new_hot_counts),
                      seen_of(state.hot.seen), hot_aux)
    cold = BufferState(
        jax.tree_util.tree_unflatten(cold_def, [jnp.asarray(l) for l in new_cold]),
        jnp.asarray(new_cold_counts), seen_of(state.cold.seen), state.cold.aux)
    stage, stage_labels, stage_valid = _reshard_stage(
        state.stage, state.stage_labels, state.stage_valid, n_new)
    return TieredState(hot, cold, stage, stage_labels, stage_valid)


def reshard_carry(carry: TrainCarry, n_new: int, policy=None) -> TrainCarry:
    """Adapt a TrainCarry saved with N workers to ``n_new`` workers.

    ``policy`` (name or Policy) must identify the buffer policy when it carries
    aux state — resharding compacts each worker's slots, so cloned aux (FIFO
    cursor, GRASP distances) would be misaligned; it is rebuilt per worker via
    ``Policy.reshard_aux``. Flat and tiered buffers both reshard; see
    ``reshard_tiered`` for the tier-by-tier semantics."""
    if carry.buffer is None:
        return carry
    if isinstance(carry.buffer, TieredState):
        buffer: Any = reshard_tiered(carry.buffer, n_new, policy)
    else:
        buffer = _reshard_buffer_state(carry.buffer, n_new, policy)

    def resize_reps(x):
        x = np.asarray(x)
        if n_new <= x.shape[0]:
            return jnp.asarray(x[:n_new])
        tiles = -(-n_new // x.shape[0])  # ceil: handles n_new > 2x the old count
        return jnp.asarray(np.concatenate([x] * tiles, axis=0)[:n_new])

    pipe = carry.pipe
    if pipe is not None:
        pipe = PipelinedRehearsalCarry(
            jax.tree_util.tree_map(resize_reps, pipe.reps),
            resize_reps(pipe.valid),
            pipe.key,
        )
    return TrainCarry(carry.params, carry.opt, buffer, pipe, carry.ef)
