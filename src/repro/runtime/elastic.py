"""Elastic scaling: resume a run on a different worker count.

The contract: everything in the carry is either *replicated* (params, optimizer — a
new worker count changes only how GSPMD lays them out) or *per-worker* (rehearsal
buffer, in-flight representatives — redistributed by ``reshard_buffer``). The data
pipeline re-shards trivially (cursor-deterministic streams).

Shrink (N→N′<N): buffer contents are pooled per bucket and re-dealt; aggregate
capacity drops to N′·S_max exactly as the paper's scaling law predicts.
Grow (N→N′>N): new workers start with partially-filled buffers and fill via Alg-1.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import reshard_buffer
from repro.core.rehearsal import BufferState
from repro.core.strategies import PipelinedRehearsalCarry, TrainCarry


def reshard_carry(carry: TrainCarry, n_new: int) -> TrainCarry:
    """Adapt a TrainCarry saved with N workers to ``n_new`` workers."""
    if carry.buffer is None:
        return carry
    new_data, new_counts = reshard_buffer(carry.buffer.data, np.asarray(carry.buffer.counts),
                                          n_new)
    n_old, k = np.asarray(carry.buffer.counts).shape
    seen = np.asarray(carry.buffer.seen).sum(axis=0, keepdims=True)
    new_seen = np.broadcast_to(seen // n_new, (n_new, k)).copy()
    buffer = BufferState(
        data=jax.tree_util.tree_map(jnp.asarray, new_data),
        counts=jnp.asarray(new_counts),
        seen=jnp.asarray(new_seen.astype(np.int32)),
    )

    def resize_reps(x):
        x = np.asarray(x)
        if n_new <= x.shape[0]:
            return jnp.asarray(x[:n_new])
        reps = np.concatenate([x] + [x[: n_new - x.shape[0]]], axis=0)
        return jnp.asarray(reps)

    pipe = carry.pipe
    if pipe is not None:
        pipe = PipelinedRehearsalCarry(
            jax.tree_util.tree_map(resize_reps, pipe.reps),
            resize_reps(pipe.valid),
            pipe.key,
        )
    return TrainCarry(carry.params, carry.opt, buffer, pipe, carry.ef)
