"""Elastic scaling: resume a run on a different worker count.

The contract: everything in the carry is either *replicated* (params, optimizer — a
new worker count changes only how GSPMD lays them out) or *per-worker* (rehearsal
buffer, in-flight representatives — redistributed by ``reshard_buffer``). The data
pipeline re-shards trivially (cursor-deterministic streams).

Shrink (N→N′<N): buffer contents are pooled per bucket and re-dealt; aggregate
capacity drops to N′·S_max exactly as the paper's scaling law predicts.
Grow (N→N′>N): new workers start with partially-filled buffers and fill via Alg-1.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import reshard_buffer
from repro.core.rehearsal import BufferState
from repro.core.strategies import PipelinedRehearsalCarry, TrainCarry


def reshard_carry(carry: TrainCarry, n_new: int, policy=None) -> TrainCarry:
    """Adapt a TrainCarry saved with N workers to ``n_new`` workers.

    ``policy`` (name or Policy) must identify the buffer policy when it carries
    aux state — resharding compacts each worker's slots, so cloned aux (FIFO
    cursor, GRASP distances) would be misaligned; it is rebuilt per worker via
    ``Policy.reshard_aux``."""
    if carry.buffer is None:
        return carry
    if not isinstance(carry.buffer, BufferState):
        raise NotImplementedError(
            "elastic resharding of tiered buffers is not supported yet; "
            "drain the cold tier (tiering='off') before changing worker count"
        )
    new_data, new_counts = reshard_buffer(carry.buffer.data, np.asarray(carry.buffer.counts),
                                          n_new)
    n_old, k = np.asarray(carry.buffer.counts).shape
    seen = np.asarray(carry.buffer.seen).sum(axis=0, keepdims=True)
    new_seen = np.broadcast_to(seen // n_new, (n_new, k)).copy()

    def resize_reps(x):
        x = np.asarray(x)
        if n_new <= x.shape[0]:
            return jnp.asarray(x[:n_new])
        tiles = -(-n_new // x.shape[0])  # ceil: handles n_new > 2x the old count
        return jnp.asarray(np.concatenate([x] * tiles, axis=0)[:n_new])

    if jax.tree_util.tree_leaves(carry.buffer.aux):
        from repro.buffer import resolve_policy

        if policy is None:
            raise ValueError(
                "the buffer carries policy aux state; pass the policy (name or "
                "Policy) so reshard_carry can rebuild it for the re-dealt slots"
            )
        pol = resolve_policy(policy)
        per_worker = [
            pol.reshard_aux(
                jax.tree_util.tree_map(lambda x: jnp.asarray(np.asarray(x)[w]),
                                       new_data),
                new_counts[w],
            )
            for w in range(n_new)
        ]
        aux = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_worker)
    else:
        aux = carry.buffer.aux
    buffer = BufferState(
        data=jax.tree_util.tree_map(jnp.asarray, new_data),
        counts=jnp.asarray(new_counts),
        seen=jnp.asarray(new_seen.astype(np.int32)),
        aux=aux,
    )

    pipe = carry.pipe
    if pipe is not None:
        pipe = PipelinedRehearsalCarry(
            jax.tree_util.tree_map(resize_reps, pipe.reps),
            resize_reps(pipe.valid),
            pipe.key,
        )
    return TrainCarry(carry.params, carry.opt, buffer, pipe, carry.ef)
