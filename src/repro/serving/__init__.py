"""Online continual serving (DESIGN.md §12).

``DecodeEngine`` is the batched prefill + greedy KV-cache decode path absorbed
from ``launch.serve``; ``OnlineLearner`` interleaves it with asynchronous
rehearsal train steps so the model keeps learning from live traffic — request
batches (prompt + decode continuation) are admitted into the rehearsal buffer
between decode dispatches, train steps consume one-step-stale representatives,
and the updated params are published back to serving at each round boundary.
"""
from repro.serving.engine import DecodeEngine, GenResult
from repro.serving.online import OnlineLearner, OnlineResult

__all__ = ["DecodeEngine", "GenResult", "OnlineLearner", "OnlineResult"]
