"""Batched prefill + greedy decode engine (the serve-side half of §12).

The token math is the historical ``launch/serve.py`` loop verbatim — prefill
feeds the prompt one position at a time through the decode step (cache-building
prefill), then greedy argmax generation continues to ``prompt_len + gen_len``.
That loop is the bit-exactness contract: with online learning disabled, a
``DecodeEngine`` produces the identical token ids the pre-serving-subsystem
script printed (tests/test_serving.py::test_engine_matches_legacy_serve_loop).
"""
from __future__ import annotations

import time
from typing import NamedTuple

import jax
import jax.numpy as jnp


class GenResult(NamedTuple):
    tokens: jax.Array  # [batch, gen_len] greedy continuation ids
    prefill_seconds: float
    decode_seconds: float
    tokens_per_second: float  # per-sequence decode throughput


class DecodeEngine:
    """Holds the model + forward context + the jitted decode step.

    ``ctx`` is the serving ``StackCtx`` (its compute dtype is the ``--dtype``
    knob; shard fn set when serving under a mesh). The engine is stateless
    across calls — params are an argument, which is what makes the online
    weight handoff a plain swap of the array the caller passes in.
    """

    def __init__(self, model, ctx, cache_dtype=jnp.float32):
        self.model = model
        self.ctx = ctx
        self.cache_dtype = cache_dtype
        self._decode = jax.jit(
            lambda p, b, c, i: model.decode(p, b, c, i, ctx))

    def generate(self, params, prompts, gen_len: int) -> GenResult:
        """Prefill ``prompts`` [batch, prompt_len], then greedily decode
        ``gen_len`` tokens. Pure function of (params, prompts)."""
        from repro.obs import get_tracer
        tracer = get_tracer()

        batch, prompt_len = prompts.shape
        max_len = prompt_len + gen_len
        caches = self.model.init_cache(params, batch, max_len,
                                       dtype=self.cache_dtype)
        t0 = time.time()
        logits = None
        with tracer.span("prefill", cat="serve", tokens=prompt_len,
                         batch=batch):
            for t in range(prompt_len):
                logits, caches = self._decode(
                    params, {"token": prompts[:, t:t + 1]}, caches,
                    jnp.int32(t))
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        out = [tok]
        t0 = time.time()
        with tracer.span("decode", cat="serve", tokens=gen_len, batch=batch):
            for t in range(prompt_len, max_len - 1):
                logits, caches = self._decode(params, {"token": tok}, caches,
                                              jnp.int32(t))
                tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
                out.append(tok)
            jax.block_until_ready(tok)
        t_gen = time.time() - t0
        gen = jnp.concatenate(out, axis=1)
        return GenResult(tokens=gen, prefill_seconds=t_prefill,
                         decode_seconds=t_gen,
                         tokens_per_second=gen.shape[1] / max(t_gen, 1e-9))
