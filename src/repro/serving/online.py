"""OnlineLearner: the serve/train interleave (DESIGN.md §12).

Each round serves one request batch through the :class:`DecodeEngine`, admits
the traffic (prompt + the decode continuation, re-labelled by content bucket)
into the distributed rehearsal buffer, and runs ``train_every`` rehearsal
steps whose representatives are one-step stale — the paper's trick applied to
the serve/train boundary: the all_to_all and the weight update issued for
round *r* never block round *r*'s decode dispatches, and the params they
produce are published to serving at the round boundary (the weight handoff;
with the fused step's donated carry this is a pointer swap, not a copy).

Failure containment: with ``run.resilience`` configured the train steps run
inside a ``runtime.ResilientLoop`` (checkpointed restarts under
``ckpt_dir/resilient``); if even its restart budget is exhausted, training is
disabled for the rest of the session and serving continues from the last
checkpointed weights. Without a resilience config the carry is kept undonated
so a failed train step simply leaves the previous round's weights serving.
A train failure therefore never kills serving, in either mode.

Freshness is measured in *rounds since the last weight handoff* as seen by the
serving step: steady-state value 1 — exactly the one-step staleness the paper
trades for never blocking.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig
from repro.scenario import ContinualTrainer
from repro.scenario.scenarios import build_token_lm
from repro.serving.engine import DecodeEngine, GenResult


class OnlineResult(NamedTuple):
    history: List[Dict[str, float]]  # one entry per serve round
    decode_tokens_per_second: float  # mean per-sequence decode throughput
    admission_rate: float  # admitted request rows / served request rows
    freshness_rounds: float  # freshness the final round decoded with (steady state: 1)
    accuracy: List[float]  # per-anchor-phase next-token accuracy at the end
    restarts: int  # ResilientLoop restarts absorbed by the train side
    train_disabled: bool  # True if the restart budget was exhausted
    freshness_evals: List[Dict[str, float]]  # periodic drifted-slice evals
    params: Any  # the weights serving ended on
    carry: Any  # full train carry (buffer + pipeline state)
    last_tokens: Any  # [batch, gen_len] ids of the final round's decode


class OnlineLearner:
    """Interleaved serve/train loop over a task-free traffic stream.

    Args:
      run: ``RunConfig``; ``run.online`` holds the interleave knobs,
        ``run.scenario`` names the traffic scenario (default ``drift_stream``),
        ``run.rehearsal``/``run.strategy`` shape the buffer exactly as in
        offline training, and ``run.resilience`` (requires ``ckpt_dir``)
        arms the checkpointed-restart path.
      scenario: optional explicit Scenario (else resolved from ``run``). Must
        be a token scenario whose records carry ``tokens``/``labels`` rows.
      ckpt_dir: directory for the ResilientLoop's restart checkpoints.
      serve_dtype: compute dtype of the *serving* forward (the ``--dtype``
        flag); training keeps ``run.train.compute_dtype``.
      registry: optional ``obs.MetricsRegistry`` — the learner maintains the
        ``repro_online_*`` gauges on it.
      failure_hook: chaos injection point, called with the absolute train-step
        id before each train step (tests inject ``InjectedFailure``).
    """

    def __init__(self, run: RunConfig, scenario=None, *, ckpt_dir: str = "",
                 exchange: str = "full", serve_dtype=jnp.float32,
                 registry=None, failure_hook=None):
        self.run_config = run
        self.ocfg = run.online
        self.registry = registry
        # The trainer composes the whole train side (scenario defaults ->
        # rcfg, strategy aux fields, fused make_cl_step, ResilientLoop).
        # Donation policy: with resilience the checkpoint is the recovery
        # path, so the step may donate its carry (the swap-handoff); without
        # it the undonated previous carry IS the recovery path.
        self.trainer = ContinualTrainer(
            run, scenario, exchange=exchange, ckpt_dir=ckpt_dir,
            prefetch=False, donate=run.resilience is not None,
            overrides={"failure_hook": failure_hook} if failure_hook else None)
        tr = self.trainer
        if tr.scenario is None or "tokens" not in tr.scenario.item_spec:
            raise ValueError(
                "OnlineLearner needs a token scenario (records with "
                "'tokens'/'labels' rows); got "
                f"{getattr(tr.scenario, 'name', None)!r}")
        if tr._step_fn is None:
            raise ValueError("OnlineLearner needs the fused carry-backend "
                             "step (mesh pjit serving is not wired yet)")
        self.scenario = tr.scenario
        self.seq_len = self.scenario.item_spec["tokens"].shape[0]
        self.gen_len = self.ocfg.resolved_gen_len(self.seq_len)
        if (self.ocfg.enabled and self.ocfg.store_decode
                and self.ocfg.prompt_len + self.gen_len != self.seq_len + 1):
            raise ValueError(
                f"prompt_len={self.ocfg.prompt_len} + gen_len={self.gen_len} "
                f"must equal seq_len+1={self.seq_len + 1} so admitted records "
                f"fill the scenario's [seq_len] token/label layout "
                f"(store_decode=False lifts this)")
        # The serving forward: same model tree as the train side (both come
        # from build_token_lm on the same run), its own dtype/remat context.
        model, _, _ = build_token_lm(
            run, getattr(self.scenario.stream.cfg, "vocab_size", 0))
        from repro.models import StackCtx
        self.engine = DecodeEngine(
            model, StackCtx(cfg=model.cfg, compute_dtype=serve_dtype,
                            remat="none"),
            cache_dtype=serve_dtype)

    # ------------------------------------------------------------------ admit
    def _admit_records(self, req: Dict[str, np.ndarray],
                       gen: GenResult) -> Dict[str, jnp.ndarray]:
        """Build buffer records from one round of traffic. With
        ``store_decode`` the record is prompt ++ continuation (the
        model-outputs side of the stream) shifted into (tokens, labels);
        otherwise the raw request rows. The bucket ``label`` is recomputed
        from the record's own content — generated tokens may wander across
        vocab bands, and admission must bucket what is actually stored."""
        if self.ocfg.store_decode:
            prompts = np.asarray(req["tokens"][:, :self.ocfg.prompt_len])
            full = np.concatenate([prompts, np.asarray(gen.tokens)], axis=1)
            tokens = full[:, :-1].astype(np.int32)
            labels = full[:, 1:].astype(np.int32)
        else:
            tokens = np.asarray(req["tokens"], np.int32)
            labels = np.asarray(req["labels"], np.int32)
        rec = {"tokens": tokens, "labels": labels}
        bucket = self.scenario.buffer_task_field
        if bucket in self.scenario.item_spec and bucket not in rec:
            stream = self.scenario.stream
            if hasattr(stream, "bucket_of"):
                rec[bucket] = stream.bucket_of(tokens)
            else:
                rec[bucket] = np.asarray(req[bucket], np.int32)
        return {k: jnp.asarray(v) for k, v in rec.items()}

    # -------------------------------------------------------------------- run
    def run(self) -> OnlineResult:
        from repro.obs import get_event_bus, get_tracer
        from repro.strategy import init_carry

        tr, ocfg = self.trainer, self.ocfg
        tracer, bus = get_tracer(), get_event_bus()
        key = jax.random.PRNGKey(tr.seed)
        params = tr.init_params_fn(key)
        carry = init_carry(params, tr.init_opt_fn(params), tr.item_spec,
                           tr.rcfg, label_field=tr.label_field, seed=tr.seed)
        rloop = None
        tmpl = None
        if tr.resilience is not None:
            rloop = tr._resilient_loop(tr._step_fn, tr._stale_step_fn)
            # host-side template for the exhausted-budget restore: after the
            # step donates the carry, only the checkpoint can resurrect it
            tmpl = jax.tree_util.tree_map(np.asarray, carry)

        history: List[Dict[str, float]] = []
        freshness_evals: List[Dict[str, float]] = []
        tok_s: List[float] = []
        served = admitted = 0
        restarts = 0
        train_disabled = False
        last_handoff = -1  # "round" whose training produced current params
        train_step = 0
        last_tokens = None

        for r in range(ocfg.rounds):
            req = self.scenario.batch(0, ocfg.requests_per_round, r)
            prompts = jnp.asarray(req["tokens"][:, :ocfg.prompt_len])
            freshness = r - last_handoff
            self._gauge("repro_online_freshness_rounds", freshness,
                        help="serve rounds since the last weight handoff "
                             "(steady state: 1 = one-step staleness)")
            san = getattr(tr._step_fn, "_sanitizer", None)
            if san is not None:
                # the weight handoff must publish live arrays: serving from a
                # donated (deleted) params tree is the exact race this guards
                san.check_live(carry.params, "serving params")
            with tracer.span("serve_round", cat="serving", round=r,
                             freshness=freshness):
                res = self.engine.generate(carry.params, prompts, self.gen_len)
            last_tokens = res.tokens
            served += int(prompts.shape[0])
            tok_s.append(res.tokens_per_second)

            trained = False
            loss = float("nan")
            if ocfg.enabled and ocfg.train_every > 0 and not train_disabled:
                records = self._admit_records(req, res)
                with tracer.span("online_train", cat="serving", round=r,
                                 steps=ocfg.train_every):
                    try:
                        if rloop is not None:
                            carry, hist, _ = rloop.run(
                                carry, lambda s, _rec=records: _rec, key,
                                ocfg.train_every, start_step=train_step,
                                failure_hook=self.trainer._failure_hook)
                            restarts += int(rloop.stats.get("restarts", 0))
                            metrics = hist[-1] if hist else {}
                        else:
                            hook = self.trainer._failure_hook
                            for i in range(ocfg.train_every):
                                if hook is not None:
                                    hook(train_step + i)
                                carry, metrics = tr._step_fn(
                                    carry, records,
                                    jax.random.fold_in(key, train_step + i))
                        trained = True
                    except Exception as e:  # noqa: BLE001 — serve must survive
                        train_disabled = True
                        if rloop is not None and tmpl is not None:
                            # the donated carry is gone; fall back to the last
                            # checkpointed state and keep serving from it
                            restored, _ = rloop.ckpt.restore(tmpl)
                            carry = jax.tree_util.tree_map(jnp.asarray,
                                                           restored)
                        bus.publish("online_train_disabled", source="serving",
                                    round=r, error=type(e).__name__,
                                    detail=str(e)[:200])
                if trained:
                    train_step += ocfg.train_every
                    admitted += int(prompts.shape[0])
                    loss = float(metrics.get("loss", float("nan")))
                    with tracer.span("weight_handoff", cat="serving", round=r):
                        # publish: next round's decode reads the new params
                        jax.block_until_ready(carry.params)
                    last_handoff = r
                    bus.publish("online_admit", source="serving", round=r,
                                rows=int(prompts.shape[0]),
                                buffer_fill=float(metrics.get(
                                    "buffer_fill", float("nan"))))

            rate = admitted / max(served, 1)
            self._gauge("repro_online_admission_rate", rate,
                        help="admitted request rows / served request rows")
            self._gauge("repro_online_decode_tokens_per_second",
                        res.tokens_per_second,
                        help="per-sequence greedy decode throughput")
            bus.publish("online_round", source="serving", round=r,
                        trained=trained, tokens_per_second=res.tokens_per_second,
                        freshness=freshness)
            history.append({"round": r, "loss": loss, "trained": float(trained),
                            "freshness": float(freshness),
                            "tokens_per_second": res.tokens_per_second,
                            "admission_rate": rate})
            if (ocfg.freshness_every and (r + 1) % ocfg.freshness_every == 0
                    and tr.eval_fn is not None):
                phase, _ = self.scenario.stream.phase_weight(r) \
                    if hasattr(self.scenario.stream, "phase_weight") else (0, 0)
                freshness_evals.append({
                    "round": r, "phase": phase,
                    "accuracy": tr.eval_fn(carry.params, phase)})

        accuracy = []
        if tr.eval_fn is not None:
            accuracy = [tr.eval_fn(carry.params, p)
                        for p in range(tr.num_tasks)]
        self._gauge("repro_online_restarts", restarts,
                    help="train-side ResilientLoop restarts absorbed")
        return OnlineResult(
            history=history,
            decode_tokens_per_second=float(np.mean(tok_s)) if tok_s else 0.0,
            admission_rate=admitted / max(served, 1),
            freshness_rounds=float(history[-1]["freshness"]) if history else 0.0,
            accuracy=accuracy, restarts=restarts,
            train_disabled=train_disabled, freshness_evals=freshness_evals,
            params=carry.params, carry=carry, last_tokens=last_tokens)

    def _gauge(self, name: str, value, help: str = ""):
        if self.registry is not None:
            self.registry.set(name, float(value), help=help)
