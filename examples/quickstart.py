"""Quickstart — the paper's experimental loop through the scenario-first API.

The paper's Listing 1:

    for i in range(no_minibatches):
        m   = DataPipeline.get_next_minibatch()
        r   = RehearsalBuffer.update(m)        # async update + global sample
        m_a = concat(m, r)
        Model.train(m_a)

is what ``ContinualTrainer.fit()`` runs inside its jitted step: the scenario
owns the task stream, ``RunConfig`` the model/optimizer/rehearsal settings,
and the trainer composes step + buffer + prefetch + the accuracy-matrix
evaluation (DESIGN.md §7). Here: a tiny LM on a 2-task token stream (CPU,
~1 min; ``--smoke`` shrinks it for CI).
"""
import argparse

from repro.configs.base import (
    ObsConfig,
    RehearsalConfig,
    RunConfig,
    ScenarioConfig,
    StrategyConfig,
    TrainConfig,
)
from repro.scenario import ContinualTrainer


def main(smoke: bool = False, strategy: str = "rehearsal", obs: str = ""):
    steps = 8 if smoke else 30
    run = RunConfig(
        # --obs DIR: jit-safe obs/* gauges in every history entry, plus
        # trace.json (Perfetto/chrome://tracing) and events.jsonl under DIR
        obs=ObsConfig(enabled=bool(obs), dir=obs),
        # model=None: the token scenario builds its default tiny LM
        train=TrainConfig(optimizer="adamw", peak_lr=3e-3, warmup_steps=10,
                          linear_scaling=False, compute_dtype="float32",
                          remat="none"),
        # the buffer subsystem is configured here: `policy` picks the
        # selection/eviction/sampling rule (reservoir | fifo | class_balanced |
        # grasp) and `tiering='host'` would spill an int8 cold tier beyond HBM
        rehearsal=RehearsalConfig(num_buckets=2, slots_per_bucket=32,
                                  num_representatives=4, num_candidates=8,
                                  mode="async", policy="reservoir",
                                  label_field="labels"),
        # the strategy picks the loss shape + buffer aux fields (repro.strategy):
        # rehearsal | der | der_pp | grasp_embed | incremental | from_scratch.
        # DER stores top-8 logits per position (8-16x smaller than the vocab row)
        strategy=StrategyConfig(alpha=0.5, beta=0.5, top_k=8),
        scenario=ScenarioConfig(name="class_incremental", modality="tokens",
                                strategy=strategy,
                                num_tasks=2, epochs_per_task=1,
                                steps_per_epoch=steps, batch_size=8,
                                vocab_size=256, seq_len=32, seed=99),
    )
    result = ContinualTrainer(run).fit()

    for h in result.history:
        print(f"task={h['task']} step={h['step']} loss={h['loss']:.4f}")
    if result.obs:
        print("obs gauges (last value):")
        for k, s in sorted(result.obs.items()):
            print(f"  {k} = {s['last']:.4f}")
        if obs:
            print(f"trace + event log under {obs}/ "
                  f"(open trace.json in https://ui.perfetto.dev)")
    # forgetting check: the metric matrix holds per-task eval LOSS for token
    # scenarios — row i is the model after training task i
    print("eval-loss matrix (row = after task i):")
    for i in range(2):
        row = " ".join(f"{result.accuracy_matrix[i, j]:6.4f}"
                       for j in range(i + 1))
        print(f"  after task {i}: {row}")
    print(f"task-0 eval loss after training both tasks (with rehearsal): "
          f"{result.accuracy_matrix[1, 0]:.4f}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (exercises the same API path)")
    ap.add_argument("--strategy", default="rehearsal",
                    help="training strategy (rehearsal | der | der_pp | "
                         "grasp_embed | incremental | from_scratch)")
    ap.add_argument("--obs", default="", metavar="DIR",
                    help="enable telemetry: obs/* gauges in the history plus "
                         "trace.json + events.jsonl under DIR")
    main(**vars(ap.parse_args()))
