"""Quickstart — the paper's Listing 1, verbatim shape, on a tiny LM (CPU, ~1 min).

    for i in range(no_minibatches):
        m   = DataPipeline.get_next_minibatch()
        r   = RehearsalBuffer.update(m)        # async update + global sample
        m_a = concat(m, r)
        Model.train(m_a)

Here ``update`` is repro.core.distributed.update_and_sample and the async double
buffering happens inside the jitted step (repro.core.strategies).
"""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.configs.base import RehearsalConfig, TrainConfig
from repro.core import init_carry, make_cl_step
from repro.data import TaskTokenStream, TokenStreamConfig
from repro.models import StackCtx, build_model
from repro.optim import make_optimizer


def main():
    # a tiny llama-family model + a 2-task token stream
    cfg = get_reduced("smollm-135m")
    cfg = type(cfg)(**{**cfg.__dict__, "vocab_size": 256, "num_layers": 2})
    model = build_model(cfg)
    ctx = StackCtx(cfg=cfg, compute_dtype=jnp.float32, remat="none")
    stream = TaskTokenStream(TokenStreamConfig(num_tasks=2, vocab_size=256, seq_len=32))

    # the buffer subsystem is configured here: `policy` picks the
    # selection/eviction/sampling rule (reservoir | fifo | class_balanced |
    # grasp), `tiering='host'` would spill an int8 cold tier beyond HBM, and
    # label_field/task_field name the record fields once, end to end.
    rcfg = RehearsalConfig(num_buckets=2, slots_per_bucket=32,
                           num_representatives=4, num_candidates=8, mode="async",
                           policy="reservoir", label_field="labels")
    opt_init, opt_update = make_optimizer(
        TrainConfig(optimizer="adamw", peak_lr=3e-3, warmup_steps=10,
                    linear_scaling=False))

    def loss_fn(params, batch):
        loss, _ = model.loss(params, batch, ctx)
        return loss, {}

    # the paper's `update` primitive lives inside this jitted step
    step = make_cl_step(loss_fn, opt_update, rcfg, strategy="rehearsal")

    key = jax.random.PRNGKey(0)
    params = model.init(key, max_seq=32)
    item_spec = {"tokens": jax.ShapeDtypeStruct((32,), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((32,), jnp.int32),
                 "task": jax.ShapeDtypeStruct((), jnp.int32)}
    carry = init_carry(params, opt_init(params), item_spec, rcfg)

    g = 0
    for task in range(2):
        for s in range(30):
            m = {k: jnp.asarray(v) for k, v in stream.batch(task, 8, g).items()}
            carry, metrics = step(carry, m, jax.random.fold_in(key, g))  # m_a inside
            g += 1
            if g % 10 == 0:
                print(f"task={task} step={g} loss={float(metrics['loss']):.4f} "
                      f"buffer_fill={int(metrics['buffer_fill'])}")

    # forgetting check: task-0 loss after task-1 training
    ev = {k: jnp.asarray(v) for k, v in stream.eval_set(0, n=16).items()}
    loss0, _ = model.loss(carry.params, ev, ctx)
    print(f"task-0 eval loss after training both tasks (with rehearsal): "
          f"{float(loss0):.4f}")


if __name__ == "__main__":
    main()
