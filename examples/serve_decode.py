"""Serving example: batched prefill + greedy decode with ring-buffer KV caches.

Uses the same decode step the decode_32k / long_500k dry-run cells lower; on SWA
architectures (try --arch mixtral-8x7b) the cache is a ring bounded by the window.
"""
import sys

from repro.launch import serve as serve_cli


def main():
    arch = sys.argv[sys.argv.index("--arch") + 1] if "--arch" in sys.argv \
        else "h2o-danube-1.8b"
    serve_cli.main([
        "--arch", arch, "--reduced",
        "--batch", "4", "--prompt-len", "32", "--gen-len", "32",
    ])


if __name__ == "__main__":
    main()
