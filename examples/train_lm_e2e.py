"""End-to-end driver: continual LM training through the production launcher.

Runs the full stack — config -> mesh -> pjit step with fused async rehearsal ->
prefetching data pipeline -> checkpointing -> per-task eval — via
``repro.launch.train``. The default preset trains a ~5M-param llama-family model for
a few hundred steps on CPU (~10 min); pass ``--full`` to use the real smollm-135m
config (sized for a TPU slice; will be slow on CPU).
"""
import sys

from repro.launch import train as train_cli


def main():
    full = "--full" in sys.argv
    argv = [
        "--arch", "smollm-135m",
        "--tasks", "2",
        "--steps-per-task", "150",
        "--seq-len", "128",
        "--global-batch", "8",
        "--mode", "async",
        "--ckpt-dir", "/tmp/repro_e2e_ckpt",
        "--ckpt-every", "100",
        "--log-every", "25",
    ]
    if not full:
        argv.append("--reduced")
    train_cli.main(argv)


if __name__ == "__main__":
    main()
