"""The paper's experiment in miniature: class-incremental vision, 3 strategies.

Reproduces the Fig. 5b ordering on CPU in ~3 minutes:
  incremental  — fast, catastrophically forgets   (paper: 23.1% top-5)
  rehearsal    — fast, retains                    (paper: 80.55%)
  from_scratch — slow (quadratic), upper bound    (paper: 91%)
"""
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import resnet50_cl
from repro.configs.base import RehearsalConfig, TrainConfig
from repro.core import make_cl_step, run_continual, topk_accuracy
from repro.data import ClassIncrementalImages, ImageStreamConfig
from repro.models.model_zoo import cross_entropy
from repro.models.resnet import apply_cnn, init_cnn
from repro.optim import make_optimizer

NUM_TASKS = 3


def main():
    stream = ClassIncrementalImages(ImageStreamConfig(
        num_tasks=NUM_TASKS, classes_per_task=5, image_size=16, noise=0.4))
    ccfg = resnet50_cl.reduced(num_classes=stream.num_classes)
    tcfg = TrainConfig(optimizer="sgd", peak_lr=0.05, warmup_steps=10,
                       linear_scaling=False)

    def loss_fn(params, batch):
        logits = apply_cnn(params, batch["images"], ccfg)
        return cross_entropy(logits[:, None, :], batch["label"][:, None]), {}

    opt_init, opt_update = make_optimizer(tcfg)
    item_spec = {"images": jax.ShapeDtypeStruct((16, 16, 3), jnp.float32),
                 "label": jax.ShapeDtypeStruct((), jnp.int32),
                 "task": jax.ShapeDtypeStruct((), jnp.int32)}
    eval_logits = jax.jit(lambda p, im: apply_cnn(p, im, ccfg))

    def eval_fn(params, task):
        ev = stream.eval_set(task)
        return float(topk_accuracy(eval_logits(params, jnp.asarray(ev["images"])),
                                   jnp.asarray(ev["label"]), k=1))

    print(f"{'strategy':>14} {'final_acc':>9} {'per-task runtimes (s)':>30}")
    for strategy, mode in [("incremental", "off"), ("rehearsal", "async"),
                           ("from_scratch", "off")]:
        rcfg = RehearsalConfig(num_buckets=NUM_TASKS, slots_per_bucket=64,
                               num_representatives=8, num_candidates=14, mode=mode)
        step = make_cl_step(loss_fn, opt_update, rcfg, strategy=strategy,
                            label_field="label")
        res = run_continual(
            strategy=strategy, num_tasks=NUM_TASKS, epochs_per_task=2,
            steps_per_epoch=15, batch_fn=stream.batch,
            cumulative_batch_fn=stream.cumulative_batch, eval_fn=eval_fn,
            init_params_fn=lambda k: init_cnn(k, ccfg), init_opt_fn=opt_init,
            step_fn=step, item_spec=item_spec, rcfg=rcfg, batch_size=24,
            label_field="label")
        rt = " ".join(f"{t:6.1f}" for t in res.task_runtimes)
        print(f"{strategy:>14} {res.final_accuracy:9.3f} {rt:>30}")
        print(f"{'':>14} accuracy matrix (row = after task i):")
        for i in range(NUM_TASKS):
            row = " ".join(f"{res.accuracy_matrix[i, j]:5.2f}" for j in range(i + 1))
            print(f"{'':>14}   after task {i}: {row}")


if __name__ == "__main__":
    main()
