"""The paper's experiment in miniature: class-incremental vision, 3 strategies.

Reproduces the Fig. 5b ordering on CPU in ~3 minutes:
  incremental  — fast, catastrophically forgets   (paper: 23.1% top-5)
  rehearsal    — fast, retains                    (paper: 80.55%)
  from_scratch — slow (quadratic), upper bound    (paper: 91%)

Each strategy is one ``ContinualTrainer.fit()`` over the same scenario — the
scenario owns the stream, the trainer owns the wiring (DESIGN.md §7).
"""
import dataclasses

from repro.configs.base import (
    RehearsalConfig,
    RunConfig,
    ScenarioConfig,
    TrainConfig,
)
from repro.scenario import ClassIncremental, ContinualTrainer

NUM_TASKS = 3


def main():
    scenario_cfg = ScenarioConfig(name="class_incremental", num_tasks=NUM_TASKS,
                                  classes_per_task=5, image_size=16, noise=0.4,
                                  epochs_per_task=2, steps_per_epoch=15,
                                  batch_size=24)
    scenario = ClassIncremental(scenario_cfg)  # shared stream across strategies
    base = RunConfig(
        train=TrainConfig(optimizer="sgd", peak_lr=0.05, warmup_steps=10,
                          linear_scaling=False),
        rehearsal=RehearsalConfig(num_buckets=NUM_TASKS, slots_per_bucket=64,
                                  num_representatives=8, num_candidates=14,
                                  mode="async"),
        scenario=scenario_cfg,
    )

    print(f"{'strategy':>14} {'final_acc':>9} {'per-task runtimes (s)':>30}")
    for strategy in ("incremental", "rehearsal", "from_scratch"):
        run = dataclasses.replace(
            base, scenario=dataclasses.replace(scenario_cfg, strategy=strategy))
        res = ContinualTrainer(run, scenario).fit()
        rt = " ".join(f"{t:6.1f}" for t in res.task_runtimes)
        print(f"{strategy:>14} {res.final_accuracy:9.3f} {rt:>30}")
        print(f"{'':>14} accuracy matrix (row = after task i):")
        for i in range(NUM_TASKS):
            row = " ".join(f"{res.accuracy_matrix[i, j]:5.2f}" for j in range(i + 1))
            print(f"{'':>14}   after task {i}: {row}")


if __name__ == "__main__":
    main()
